"""Bulk-engine parity suite: the vectorized scatter-arbitration build
(repro.core.bulk, backend="jax") must be *bit-exact* against the
sequential-scan reference (backend="scan") — identical store planes,
identical live counts, identical per-element STATUS codes — across
duplicates-in-batch, tombstone reuse, masks, near-full tables, u64
(2-word) keys, and every probing scheme/window combination."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bulk
from repro.core import counting as ct
from repro.core import hashset as hs
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.relational import groupby as gb


def assert_tables_equal(tb, ts, stb=None, sts=None):
    """Bit-exact comparison: store planes, count, statuses."""
    for pb, ps in zip(jax.tree_util.tree_leaves(tb.store),
                      jax.tree_util.tree_leaves(ts.store)):
        np.testing.assert_array_equal(np.asarray(pb), np.asarray(ps))
    assert int(tb.count) == int(ts.count)
    if stb is not None:
        np.testing.assert_array_equal(np.asarray(stb), np.asarray(sts))


def _pair(create_fn, **kw):
    return create_fn(backend="jax", **kw), create_fn(backend="scan", **kw)


class TestInsertParity:
    def test_duplicates_and_masks(self):
        rng = np.random.default_rng(0)
        keys = jnp.asarray(rng.integers(1, 150, 400, dtype=np.uint32))
        vals = jnp.asarray(rng.integers(0, 2 ** 32 - 2, 400, dtype=np.uint32))
        mask = jnp.asarray(rng.random(400) < 0.8)
        tb, ts = _pair(lambda **kw: sv.create(1024, window=16, **kw))
        tb, stb = sv.insert(tb, keys, vals, mask)
        ts, sts = sv.insert(ts, keys, vals, mask)
        assert_tables_equal(tb, ts, stb, sts)

    def test_near_full_and_full_statuses(self):
        rng = np.random.default_rng(1)
        keys = jnp.asarray(rng.permutation(
            np.arange(1, 120, dtype=np.uint32)))
        tb, ts = _pair(lambda **kw: sv.create(64, window=8, max_probes=16,
                                              **kw))
        tb, stb = sv.insert(tb, keys, keys)
        ts, sts = sv.insert(ts, keys, keys)
        assert_tables_equal(tb, ts, stb, sts)

    def test_tombstone_reuse(self):
        keys = jnp.arange(1, 120, dtype=jnp.uint32)
        tb, ts = _pair(lambda **kw: sv.create(64, window=8, max_probes=16,
                                              **kw))
        tb, _ = sv.insert(tb, keys, keys)
        ts, _ = sv.insert(ts, keys, keys)
        tb, eb = sv.erase(tb, keys[:40])
        ts, es = sv.erase(ts, keys[:40])
        np.testing.assert_array_equal(np.asarray(eb), np.asarray(es))
        tb, stb = sv.insert(tb, keys[:80], keys[:80] ^ 7)
        ts, sts = sv.insert(ts, keys[:80], keys[:80] ^ 7)
        assert_tables_equal(tb, ts, stb, sts)

    def test_u64_two_word_keys(self):
        rng = np.random.default_rng(2)
        kk = rng.integers(0, 2 ** 32 - 2, (150, 2), dtype=np.uint32)
        kk = np.concatenate([kk, kk[:30]])           # duplicates
        vv = jnp.asarray(rng.integers(0, 2 ** 32 - 2, (180, 2),
                                      dtype=np.uint32))
        tb, ts = _pair(lambda **kw: sv.create(512, key_words=2, value_words=2,
                                              window=8, **kw))
        tb, stb = sv.insert(tb, jnp.asarray(kk), vv)
        ts, sts = sv.insert(ts, jnp.asarray(kk), vv)
        assert_tables_equal(tb, ts, stb, sts)

    @pytest.mark.parametrize("layout", ["soa", "aos", "packed"])
    def test_layouts(self, layout):
        rng = np.random.default_rng(3)
        keys = jnp.asarray(rng.integers(1, 100, 200, dtype=np.uint32))
        tb, ts = _pair(lambda **kw: sv.create(512, layout=layout, window=16,
                                              **kw))
        tb, stb = sv.insert(tb, keys, keys * 3)
        ts, sts = sv.insert(ts, keys, keys * 3)
        assert_tables_equal(tb, ts, stb, sts)

    def test_hashset_zero_value_words(self):
        keys = jnp.asarray([5, 9, 5, 11, 9, 5], jnp.uint32)
        sb, ss = _pair(lambda **kw: hs.create(128, **kw))
        sb, nb = hs.add(sb, keys)
        ss, ns = hs.add(ss, keys)
        np.testing.assert_array_equal(np.asarray(nb), np.asarray(ns))
        assert int(sb.count) == int(ss.count)


class TestMultiValueParity:
    def test_duplicate_keys_distinct_slots(self):
        rng = np.random.default_rng(4)
        keys = jnp.asarray(rng.integers(1, 20, 200, dtype=np.uint32))
        vals = jnp.arange(200, dtype=jnp.uint32)
        mask = jnp.asarray(rng.random(200) < 0.8)
        tb, ts = _pair(lambda **kw: mv.create(1024, window=16, **kw))
        tb, stb = mv.insert(tb, keys, vals, mask)
        ts, sts = mv.insert(ts, keys, vals, mask)
        assert_tables_equal(tb, ts, stb, sts)

    def test_near_full_heavy_duplicates(self):
        rng = np.random.default_rng(5)
        keys = jnp.asarray(rng.integers(1, 6, 100, dtype=np.uint32))
        tb, ts = _pair(lambda **kw: mv.create(64, window=8, max_probes=16,
                                              **kw))
        tb, stb = mv.insert(tb, keys, keys * 3)
        ts, sts = mv.insert(ts, keys, keys * 3)
        assert_tables_equal(tb, ts, stb, sts)


class TestRmwParity:
    def test_counting(self):
        rng = np.random.default_rng(6)
        keys = jnp.asarray(rng.integers(1, 50, 300, dtype=np.uint32))
        tb, ts = _pair(lambda **kw: ct.create(256, **kw))
        tb, stb = ct.insert(tb, keys)
        ts, sts = ct.insert(ts, keys)
        assert_tables_equal(tb, ts, stb, sts)

    @pytest.mark.parametrize("agg", gb.AGGS)
    def test_groupby_all_aggs(self, agg):
        rng = np.random.default_rng(7)
        keys = jnp.asarray(rng.integers(1, 40, 250, dtype=np.uint32))
        vals = jnp.asarray(rng.integers(0, 1 << 20, 250, dtype=np.uint32))
        mask = jnp.asarray(rng.random(250) < 0.8)
        tb, ts = _pair(lambda **kw: gb.create(256, **kw))
        tb, stb = gb.update(tb, agg, keys, vals, mask)
        ts, sts = gb.update(ts, agg, keys, vals, mask)
        assert_tables_equal(tb, ts, stb, sts)

    def test_second_batch_folds_into_existing(self):
        rng = np.random.default_rng(8)
        keys = jnp.asarray(rng.integers(1, 30, 150, dtype=np.uint32))
        vals = jnp.asarray(rng.integers(0, 1 << 16, 150, dtype=np.uint32))
        tb, ts = _pair(lambda **kw: gb.create(256, **kw))
        tb, _ = gb.update(tb, "min", keys[:70], vals[:70])
        ts, _ = gb.update(ts, "min", keys[:70], vals[:70])
        tb, stb = gb.update(tb, "min", keys, vals)
        ts, sts = gb.update(ts, "min", keys, vals)
        assert_tables_equal(tb, ts, stb, sts)

    @pytest.mark.parametrize("name,fold", [
        ("or", lambda old, key, new: old | new),
        ("and", lambda old, key, new: old & new),
        ("xor", lambda old, key, new: old ^ new),
    ])
    def test_bitwise_specs(self, name, fold):
        """("or",)/("and",)/("xor",) specs run the bit-plane scatter-reduce
        fast lane and must match the sequential fold bit for bit — the
        bloom-style value lane (set-union values via bitwise-or)."""
        rng = np.random.default_rng(hash(name) % 2 ** 31)
        n = 300
        keys = jnp.asarray(rng.integers(1, 25, n, dtype=np.uint32))
        vals = jnp.asarray(rng.integers(0, 2 ** 32 - 2, n, dtype=np.uint32))
        init = jnp.asarray(rng.integers(0, 2 ** 32 - 2, n, dtype=np.uint32))
        mask = jnp.asarray(rng.random(n) < 0.75)
        tb, ts = _pair(lambda **kw: sv.create(256, **kw))
        pre = keys[: n // 2]                  # existing keys exercise RMW
        tb, _ = sv.insert(tb, pre, pre)
        ts, _ = sv.insert(ts, pre, pre)
        tb, stb = sv.update_values(tb, keys, fold, init, mask=mask,
                                   values=vals, combine=(name,))
        ts, sts = sv.update_values(ts, keys, fold, init, mask=mask,
                                   values=vals)
        assert_tables_equal(tb, ts, stb, sts)

    def test_bitwise_spec_multiword(self):
        """Mixed per-word specs — ("or", "add") — on 2-word values."""
        rng = np.random.default_rng(11)
        n = 150
        keys = jnp.asarray(rng.integers(1, 20, n, dtype=np.uint32))
        vals = jnp.asarray(rng.integers(0, 2 ** 31, (n, 2), dtype=np.uint32))
        init = jnp.asarray(rng.integers(0, 2 ** 31, (n, 2), dtype=np.uint32))
        fold = lambda old, key, new: jnp.stack([old[0] | new[0],
                                                old[1] + new[1]])
        tb, ts = _pair(lambda **kw: sv.create(256, value_words=2, **kw))
        tb, stb = sv.update_values(tb, keys, fold, init, values=vals,
                                   combine=("or", "add"))
        ts, sts = sv.update_values(ts, keys, fold, init, values=vals)
        assert_tables_equal(tb, ts, stb, sts)

    def test_bitwise_combine_callable_roundtrip(self):
        """COMBINE_OPS entries for the bitwise specs lift into the general
        lane's callable form (combine_callable) with the same identity."""
        a = jnp.asarray([0b1010], jnp.uint32)
        b = jnp.asarray([0b0110], jnp.uint32)
        assert int(bulk.combine_callable(("or",))(a, b)[0]) == 0b1110
        assert int(bulk.combine_callable(("and",))(a, b)[0]) == 0b0010
        assert int(bulk.combine_callable(("xor",))(a, b)[0]) == 0b1100
        for name in ("or", "and", "xor"):
            ident, op = bulk.COMBINE_OPS[name]
            assert int(op(a, jnp.asarray([ident]))[0]) == int(a[0])

    def test_general_lane_callable_combine(self):
        """An arbitrary (associative) combiner callable takes the sorted
        general lane; same parity contract."""
        keys = jnp.asarray([3, 3, 7, 3, 9, 7], jnp.uint32)
        vals = jnp.asarray([1, 2, 3, 4, 5, 6], jnp.uint32)
        fold = lambda old, key, new: jnp.maximum(old, new)
        cmb = lambda a, b: jnp.maximum(a, b)
        tb, ts = _pair(lambda **kw: sv.create(128, **kw))
        tb, stb = sv.update_values(tb, keys, fold, jnp.uint32(0),
                                   values=vals, combine=cmb)
        ts, sts = sv.update_values(ts, keys, fold, jnp.uint32(0),
                                   values=vals)
        assert_tables_equal(tb, ts, stb, sts)


class TestEraseCountDelta:
    def test_duplicate_erase_counts_once(self):
        keys = jnp.arange(1, 51, dtype=jnp.uint32)
        t = sv.create(256)
        t, _ = sv.insert(t, keys, keys)
        dup = jnp.asarray([1, 1, 2, 2, 2, 3, 99], jnp.uint32)
        t, erased = sv.erase(t, dup)
        assert np.asarray(erased).tolist() == [True] * 6 + [False]
        assert int(t.count) == 47                    # 3 distinct keys erased

    def test_masked_erase_excluded_from_delta(self):
        keys = jnp.arange(1, 21, dtype=jnp.uint32)
        t = sv.create(128)
        t, _ = sv.insert(t, keys, keys)
        mask = jnp.asarray([True, False] * 5)
        t, erased = sv.erase(t, keys[:10], mask=mask)
        assert int(np.asarray(erased).sum()) == 5
        assert int(t.count) == 15


class TestArbitrationInvariant:
    def test_placements_are_distinct_slots(self):
        """The scatter-min arena must confirm every virtual-fill placement
        is a unique (row, lane) slot."""
        rng = np.random.default_rng(9)
        keys = sv.normalize_words(
            jnp.asarray(rng.integers(1, 5000, 600, dtype=np.uint32)), 1, "k")
        table = sv.create(1024, window=16)
        words = sv.key_hash_word(keys)
        claim = jnp.ones((600,), bool)
        prio = jnp.arange(600, dtype=jnp.uint32)
        placed, row, lane, _ = bulk.place_claims(
            bulk._tstatic(table), table.store, words, claim, prio)
        win = bulk.arbitrate(row, lane, placed, prio, table.num_rows,
                             table.window)
        np.testing.assert_array_equal(np.asarray(win), np.asarray(placed))


@pytest.mark.parametrize("seed", range(8))
def test_randomized_mixed_ops(seed):
    """Randomized end-to-end: insert(dups+mask) -> erase -> reinsert, plus a
    multi-value build, across schemes/windows/capacities — bit-exact."""
    r = np.random.default_rng(seed)
    n = int(r.integers(20, 200))
    keys = jnp.asarray(r.integers(1, int(r.integers(5, 100)), n,
                                  dtype=np.uint32))
    vals = jnp.asarray(r.integers(0, 2 ** 32 - 2, n, dtype=np.uint32))
    mask = jnp.asarray(r.random(n) < 0.7)
    window = int(r.choice([1, 4, 8, 32]))
    scheme = str(r.choice(["cops", "linear", "quadratic"]))
    cap = int(r.choice([64, 256]))
    mp = int(r.choice([8, 64]))
    mk = lambda **kw: sv.create(cap, window=window, scheme=scheme,
                                max_probes=mp, **kw)
    tb, ts = _pair(mk)
    tb, s1 = sv.insert(tb, keys, vals, mask)
    ts, s2 = sv.insert(ts, keys, vals, mask)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    tb, e1 = sv.erase(tb, keys[:n // 2])
    ts, e2 = sv.erase(ts, keys[:n // 2])
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    tb, s3 = sv.insert(tb, keys, vals ^ 99)
    ts, s4 = sv.insert(ts, keys, vals ^ 99)
    assert_tables_equal(tb, ts, s3, s4)
    mb, ms = _pair(lambda **kw: mv.create(cap, window=window, scheme=scheme,
                                          max_probes=mp, **kw))
    mb, s5 = mv.insert(mb, keys, vals, mask)
    ms, s6 = mv.insert(ms, keys, vals, mask)
    assert_tables_equal(mb, ms, s5, s6)


def test_hypothesis_property_parity():
    """Hypothesis sweep (skipped when hypothesis is absent): arbitrary
    op sequences agree between bulk and scan."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(ops=st.lists(
        st.tuples(st.sampled_from(["insert", "insert", "erase"]),
                  st.lists(st.integers(1, 30), min_size=1, max_size=25)),
        min_size=1, max_size=4),
        window=st.sampled_from([4, 16]))
    def run(ops, window):
        tb, ts = _pair(lambda **kw: sv.create(128, window=window, **kw))
        for op, ks in ops:
            ka = jnp.asarray(ks, jnp.uint32)
            if op == "insert":
                va = ka * 7
                tb, s1 = sv.insert(tb, ka, va)
                ts, s2 = sv.insert(ts, ka, va)
            else:
                tb, s1 = sv.erase(tb, ka)
                ts, s2 = sv.erase(ts, ka)
            np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        assert_tables_equal(tb, ts)

    run()
