"""Training substrate: optimizers, checkpoint/reshard, compression, data."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import pipeline as dp
from repro.models import model_zoo as zoo
from repro.training import checkpoint as ckpt_mod
from repro.training import compression as comp
from repro.training import optimizer as opt_mod
from repro.training import train_loop as tl


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_smoke_config("smollm-360m")
    return cfg, zoo.build(cfg)


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "adafactor"])
    def test_reduces_loss(self, name, small_model):
        cfg, model = small_model
        ocfg = opt_mod.OptConfig(name=name, lr=3e-3, warmup_steps=1,
                                 total_steps=40)
        state = tl.init_state(model, ocfg, jax.random.PRNGKey(0))
        step = jax.jit(tl.make_train_step(model, ocfg))
        dcfg = dp.DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                             global_batch=4)
        batch = dp.get_batch(dcfg, 0)
        losses = [float(step(state, batch)[1]["loss"])]
        for _ in range(6):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]

    def test_adafactor_memory_factored(self, small_model):
        cfg, model = small_model
        params = model.init(jax.random.PRNGKey(0))
        ada = opt_mod.init(opt_mod.OptConfig(name="adafactor",
                                             factored_min_dim=8), params)
        adam = opt_mod.init(opt_mod.OptConfig(name="adamw"), params)
        assert (opt_mod.state_bytes(ada) < 0.2 * opt_mod.state_bytes(adam))

    def test_grad_clipping(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = opt_mod.clip_by_global_norm(g, 1.0)
        assert float(norm) > 300
        assert abs(float(opt_mod.global_norm(clipped)) - 1.0) < 1e-5

    def test_schedule_warmup_cosine(self):
        ocfg = opt_mod.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                 min_lr_frac=0.1)
        lrs = [float(opt_mod.schedule(ocfg, jnp.int32(s)))
               for s in [0, 5, 10, 55, 100]]
        assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
        assert abs(lrs[2] - 1.0) < 1e-6
        assert 0.1 < lrs[3] < 1.0
        assert abs(lrs[4] - 0.1) < 1e-2

    def test_accumulation_matches_full_batch(self, small_model):
        cfg, model = small_model
        ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        dcfg = dp.DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                             global_batch=8)
        batch = dp.get_batch(dcfg, 0)
        s0 = tl.init_state(model, ocfg, jax.random.PRNGKey(0))
        s1, m1 = jax.jit(tl.make_train_step(model, ocfg, accum_steps=1))(s0, batch)
        s0b = tl.init_state(model, ocfg, jax.random.PRNGKey(0))
        s4, m4 = jax.jit(tl.make_train_step(model, ocfg, accum_steps=4))(s0b, batch)
        # same data -> near-identical updates (fp32 accumulation, different order)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
            s1.params, s4.params)
        assert max(jax.tree.leaves(d)) < 5e-2


class TestCheckpoint:
    def test_roundtrip_and_prune(self, small_model):
        cfg, model = small_model
        ocfg = opt_mod.OptConfig()
        state = tl.init_state(model, ocfg, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            cm = ckpt_mod.CheckpointManager(d, keep=2)
            for s in [1, 2, 3]:
                cm.save(s, state, {"step": s})
            assert cm.all_steps() == [2, 3]
            restored, extra = cm.restore(jax.eval_shape(lambda: state))
            assert extra["step"] == 3
            for a, b in zip(jax.tree.leaves(state.params),
                            jax.tree.leaves(restored.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, small_model):
        cfg, model = small_model
        state = tl.init_state(model, opt_mod.OptConfig(),
                              jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            cm = ckpt_mod.CheckpointManager(d)
            cm.save_async(7, state, {"step": 7})
            cm.wait()
            assert cm.latest_step() == 7

    def test_bf16_preserved(self):
        tree = {"w": jnp.full((4, 4), 1.5, jnp.bfloat16),
                "s": jnp.int32(3)}
        with tempfile.TemporaryDirectory() as d:
            cm = ckpt_mod.CheckpointManager(d)
            cm.save(1, tree)
            restored, _ = cm.restore(jax.eval_shape(lambda: tree))
            assert restored["w"].dtype == jnp.bfloat16
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.asarray(tree["w"]))

    def test_atomicity_no_partial_dir(self, small_model):
        cfg, model = small_model
        state = tl.init_state(model, opt_mod.OptConfig(),
                              jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            cm = ckpt_mod.CheckpointManager(d)
            cm.save(1, state)
            entries = [e for e in os.listdir(d) if not e.startswith("step_")]
            assert entries == []


class TestCompression:
    def test_int8_error_feedback_converges(self):
        """With error feedback, repeated compression of a constant gradient
        must not lose mass (the residual carries the quantization error)."""
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(
            size=(64, 64)).astype(np.float32))}
        cfg = comp.CompressionConfig(kind="int8")
        st = comp.init_state(cfg, g)
        acc = jnp.zeros_like(g["w"])
        for _ in range(20):
            out, st = comp.compress_decompress(cfg, g, st)
            acc = acc + out["w"]
        np.testing.assert_allclose(np.asarray(acc / 20), np.asarray(g["w"]),
                                   atol=2e-3)

    def test_topk_sparsity(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(
            size=(1000,)).astype(np.float32))}
        cfg = comp.CompressionConfig(kind="topk", topk_frac=0.05)
        out, _ = comp.compress_decompress(cfg, g, comp.init_state(cfg, g))
        assert int(jnp.sum(out["w"] != 0)) == 50

    def test_quantize_dequantize_bounds(self):
        g = jnp.linspace(-3, 3, 1000)
        q, s = comp.quantize_int8(g)
        err = jnp.abs(comp.dequantize_int8(q, s) - g)
        assert float(err.max()) <= float(s) / 2 + 1e-6


class TestDataPipeline:
    def test_deterministic_per_step_and_shard(self):
        cfg = dp.DataConfig(vocab_size=100, seq_len=16, global_batch=8)
        b1 = dp.get_batch(cfg, 3, shard=1, num_shards=4)
        b2 = dp.get_batch(cfg, 3, shard=1, num_shards=4)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        b3 = dp.get_batch(cfg, 3, shard=2, num_shards=4)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b3["tokens"]))

    def test_labels_are_shifted_tokens(self):
        cfg = dp.DataConfig(vocab_size=50, seq_len=8, global_batch=2)
        b = dp.get_batch(cfg, 0)
        assert b["tokens"].shape == b["labels"].shape == (2, 8)

    def test_memmap_source(self, tmp_path):
        data = np.arange(10000, dtype=np.uint16) % 97
        path = tmp_path / "tokens.bin"
        data.tofile(path)
        cfg = dp.DataConfig(vocab_size=97, seq_len=16, global_batch=4,
                            source="memmap", path=str(path))
        b1 = dp.get_batch(cfg, 5)
        b2 = dp.get_batch(cfg, 5)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        assert int(b1["tokens"].max()) < 97

    def test_dedup_filter(self):
        from repro.core import counting
        t = counting.create(1024)
        cfg = dp.DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        toks = dp.get_batch(cfg, 0)["tokens"]
        dup = jnp.concatenate([toks, toks[:2]], axis=0)
        t, keep = dp.dedup_filter(t, dup)
        assert keep[:4].all() and not keep[4:].any()
