"""Unit tests: all six WarpCore data structures (paper §IV)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    bloom as bf,
    bucket_list as bl,
    counting as ct,
    hashset as hs,
    multi_value as mv,
    single_value as sv,
)
from repro.core.common import (
    EMPTY_KEY,
    STATUS_FULL,
    STATUS_INSERTED,
    STATUS_MASKED,
    STATUS_UPDATED,
    TOMBSTONE_KEY,
    table_geometry,
)


def test_table_geometry_prime_rows():
    rows, cap = table_geometry(1000, 32)
    assert cap == rows * 32 and cap >= 1000
    for f in range(2, int(rows ** 0.5) + 1):
        assert rows % f != 0


class TestSingleValue:
    def test_insert_retrieve_roundtrip(self):
        t = sv.create(2048, window=32)
        keys = jnp.arange(1, 1001, dtype=jnp.uint32)
        vals = keys * 7
        t, st = jax.jit(sv.insert)(t, keys, vals)
        assert (np.asarray(st) == STATUS_INSERTED).all()
        got, found = jax.jit(sv.retrieve)(t, keys)
        assert found.all() and (got == vals).all()
        assert int(t.count) == 1000

    def test_misses(self):
        t = sv.create(512)
        t, _ = sv.insert(t, jnp.arange(1, 101, dtype=jnp.uint32),
                         jnp.arange(1, 101, dtype=jnp.uint32))
        _, found = sv.retrieve(t, jnp.arange(200, 300, dtype=jnp.uint32))
        assert not found.any()

    def test_upsert_updates_value(self):
        t = sv.create(512)
        k = jnp.asarray([5, 6], jnp.uint32)
        t, _ = sv.insert(t, k, jnp.asarray([1, 2], jnp.uint32))
        t, st = sv.insert(t, k, jnp.asarray([10, 20], jnp.uint32))
        assert (np.asarray(st) == STATUS_UPDATED).all()
        got, _ = sv.retrieve(t, k)
        assert (np.asarray(got) == [10, 20]).all()
        assert int(t.count) == 2

    def test_erase_and_reinsert(self):
        t = sv.create(512)
        keys = jnp.arange(1, 101, dtype=jnp.uint32)
        t, _ = sv.insert(t, keys, keys)
        t, erased = sv.erase(t, keys[:50])
        assert erased.all() and int(t.count) == 50
        _, f = sv.retrieve(t, keys[:50])
        assert not f.any()
        _, f2 = sv.retrieve(t, keys[50:])
        assert f2.all()
        t, st = sv.insert(t, keys[:50], keys[:50] + 1)
        assert (np.asarray(st) == STATUS_INSERTED).all()
        assert int(t.count) == 100

    def test_no_duplicate_after_tombstone_reuse(self):
        # key probing past a tombstone must update, not duplicate
        t = sv.create(256, window=8)
        keys = jnp.arange(1, 101, dtype=jnp.uint32)
        t, _ = sv.insert(t, keys, keys)
        t, _ = sv.erase(t, keys[:30])
        t, st = sv.insert(t, keys[30:60], keys[30:60] * 2)  # present keys
        assert (np.asarray(st) == STATUS_UPDATED).all()
        got, f = sv.retrieve(t, keys[30:60])
        assert f.all() and (got == keys[30:60] * 2).all()

    def test_full_table_reports_full(self):
        t = sv.create(32, window=8, max_probes=16)
        cap = t.capacity
        keys = jnp.arange(1, cap + 50, dtype=jnp.uint32)   # unique keys
        t, st = sv.insert(t, keys, keys)
        st = np.asarray(st)
        count = int(t.count)
        assert count <= cap
        assert (st == STATUS_FULL).sum() == len(keys) - count
        assert (st == STATUS_INSERTED).sum() == count

    def test_masked_inserts_skipped(self):
        t = sv.create(512)
        keys = jnp.arange(1, 11, dtype=jnp.uint32)
        mask = jnp.asarray([True, False] * 5)
        t, st = sv.insert(t, keys, keys, mask=mask)
        assert (np.asarray(st)[1::2] == STATUS_MASKED).all()
        _, f = sv.retrieve(t, keys)
        assert (np.asarray(f) == np.asarray(mask)).all()

    @pytest.mark.parametrize("layout", ["soa", "aos", "packed"])
    def test_layouts_equivalent(self, layout):
        t = sv.create(1024, layout=layout, window=16)
        keys = jnp.arange(1, 501, dtype=jnp.uint32)
        t, st = sv.insert(t, keys, keys ^ jnp.uint32(0xBEEF))
        assert (np.asarray(st) == STATUS_INSERTED).all()
        got, f = sv.retrieve(t, keys)
        assert f.all() and (got == keys ^ jnp.uint32(0xBEEF)).all()

    @pytest.mark.parametrize("scheme", ["cops", "linear", "quadratic"])
    def test_probing_schemes(self, scheme):
        t = sv.create(1024, scheme=scheme, window=16)
        keys = jnp.arange(1, 701, dtype=jnp.uint32)
        t, st = sv.insert(t, keys, keys)
        assert (np.asarray(st) == STATUS_INSERTED).all()
        got, f = sv.retrieve(t, keys)
        assert f.all() and (got == keys).all()

    def test_64bit_keys_two_planes(self):
        t = sv.create(1024, key_words=2, value_words=2, window=16)
        n = 300
        rng = np.random.default_rng(3)
        keys = np.stack([rng.integers(0, 2**32 - 2, n, dtype=np.uint32),
                         rng.integers(0, 2**32 - 2, n, dtype=np.uint32)],
                        axis=1)
        keys = np.unique(keys, axis=0)
        vals = np.stack([keys[:, 0] ^ 0xAAAA, keys[:, 1] ^ 0x5555], axis=1)
        t, st = sv.insert(t, jnp.asarray(keys), jnp.asarray(vals.astype(np.uint32)))
        assert (np.asarray(st) == STATUS_INSERTED).all()
        got, f = sv.retrieve(t, jnp.asarray(keys))
        assert f.all() and (np.asarray(got) == vals).all()

    def test_high_load_factor_097(self):
        """Paper's headline: COPS stays correct at rho = 0.97."""
        t = sv.create(1024, window=32)
        n = int(t.capacity * 0.97)
        keys = jnp.arange(1, n + 1, dtype=jnp.uint32)
        t, st = sv.insert(t, keys, keys)
        assert (np.asarray(st) == STATUS_INSERTED).all()
        got, f = sv.retrieve(t, keys)
        assert f.all() and (got == keys).all()

    def test_for_each_and_for_all(self):
        t = sv.create(256)
        keys = jnp.arange(1, 51, dtype=jnp.uint32)
        t, _ = sv.insert(t, keys, keys * 3)
        out = sv.for_each(t, keys, lambda k, v, f: v[0] + 1)
        assert (np.asarray(out) == np.arange(1, 51) * 3 + 1).all()
        live = sv.for_all(t, lambda k, v, m: m)
        assert int(jnp.sum(live)) == 50


class TestMultiValue:
    def test_multiplicity_roundtrip(self):
        t = mv.create(4096, window=32)
        ks, vs, exp = [], [], {}
        for i in range(1, 201):
            m = (i % 7) + 1
            exp[i] = {i * 100 + j for j in range(m)}
            for j in range(m):
                ks.append(i)
                vs.append(i * 100 + j)
        t, st = jax.jit(mv.insert)(t, jnp.asarray(ks, jnp.uint32),
                                   jnp.asarray(vs, jnp.uint32))
        assert (np.asarray(st) == STATUS_INSERTED).all()
        q = jnp.arange(1, 201, dtype=jnp.uint32)
        cnt = mv.count_values(t, q)
        assert (np.asarray(cnt) == [(i % 7) + 1 for i in range(1, 201)]).all()
        out, off, _ = mv.retrieve_all(t, q, out_capacity=len(ks))
        out, off = np.asarray(out), np.asarray(off)
        for i in range(1, 201):
            assert set(out[off[i - 1]:off[i]].tolist()) == exp[i]

    def test_erase_all_values_of_key(self):
        t = mv.create(1024)
        keys = jnp.asarray([7] * 5 + [9] * 3, jnp.uint32)
        vals = jnp.arange(8, dtype=jnp.uint32)
        t, _ = mv.insert(t, keys, vals)
        t, cnt = mv.erase(t, jnp.asarray([7], jnp.uint32))
        assert int(cnt[0]) == 5
        c = mv.count_values(t, jnp.asarray([7, 9], jnp.uint32))
        assert np.asarray(c).tolist() == [0, 3]


class TestBucketList:
    def test_growth_and_retrieval(self):
        t = bl.create(1024, pool_capacity=16384, s0=1, growth=1.1)
        rng = np.random.default_rng(1)
        ks, vs, exp = [], [], {}
        for i in range(1, 151):
            m = int(rng.integers(1, 30))
            exp[i] = {i * 1000 + j for j in range(m)}
            for j in range(m):
                ks.append(i)
                vs.append(i * 1000 + j)
        perm = rng.permutation(len(ks))
        t, st = jax.jit(bl.insert)(
            t, jnp.asarray(np.asarray(ks, np.uint32)[perm]),
            jnp.asarray(np.asarray(vs, np.uint32)[perm]))
        assert (np.asarray(st) == STATUS_INSERTED).all()
        q = jnp.arange(1, 151, dtype=jnp.uint32)
        cnt = bl.count_values(t, q)
        assert (np.asarray(cnt) == [len(exp[i]) for i in range(1, 151)]).all()
        out, off, _ = bl.retrieve_all(t, q, out_capacity=len(ks))
        out, off = np.asarray(out), np.asarray(off)
        for i in range(1, 151):
            assert set(out[off[i - 1]:off[i]].tolist()) == exp[i]

    def test_growth_schedule(self):
        sizes, cum = bl.growth_schedule(1, 2.0, 1000)
        assert sizes[:5] == (1, 2, 4, 8, 16)
        assert cum[:6] == (0, 1, 3, 7, 15, 31)
        sizes, cum = bl.growth_schedule(4, 1.0, 100)
        assert all(s == 4 for s in sizes)

    def test_pool_exhaustion_reported(self):
        from repro.core.common import STATUS_POOL_FULL
        t = bl.create(256, pool_capacity=8, s0=4, growth=1.0)
        keys = jnp.asarray([1] * 20, jnp.uint32)
        t, st = bl.insert(t, keys, jnp.arange(20, dtype=jnp.uint32))
        st = np.asarray(st)
        assert (st == STATUS_POOL_FULL).any()
        assert int(bl.count_values(t, jnp.asarray([1], jnp.uint32))[0]) < 20

    def test_handle_packing(self):
        ptr = jnp.asarray([12345], jnp.uint32)
        h = bl.pack_handle(ptr, jnp.asarray([999]), jnp.asarray([7]),
                           jnp.asarray([bl.STATE_READY]))
        p, c, b, s = bl.unpack_handle(h)
        assert int(p[0]) == 12345 and int(c[0]) == 999
        assert int(b[0]) == 7 and int(s[0]) == bl.STATE_READY


class TestCountingAndSet:
    def test_counting(self):
        t = ct.create(512)
        keys = jnp.asarray(np.repeat(np.arange(1, 21, dtype=np.uint32), 5))
        t, _ = ct.insert(t, keys)
        c = ct.counts(t, jnp.arange(1, 21, dtype=jnp.uint32))
        assert (np.asarray(c) == 5).all()
        assert int(ct.counts(t, jnp.asarray([99], jnp.uint32))[0]) == 0

    def test_hashset(self):
        s = hs.create(512)
        s, new = hs.add(s, jnp.arange(1, 101, dtype=jnp.uint32))
        assert new.all()
        s, new2 = hs.add(s, jnp.arange(50, 151, dtype=jnp.uint32))
        assert int(new2.sum()) == 50
        assert int(hs.size(s)) == 150
        s, rem = hs.remove(s, jnp.arange(1, 51, dtype=jnp.uint32))
        assert rem.all() and int(hs.size(s)) == 100


class TestBloom:
    def test_no_false_negatives(self):
        f = bf.create(1 << 14, k=4)
        keys = jnp.arange(1, 2001, dtype=jnp.uint32)
        f = bf.insert(f, keys)
        assert bf.contains(f, keys).all()

    def test_fp_rate_reasonable(self):
        f = bf.create(1 << 15, k=4)
        f = bf.insert(f, jnp.arange(1, 1001, dtype=jnp.uint32))
        fp = bf.contains(f, jnp.arange(10 ** 6, 10 ** 6 + 10000,
                                       dtype=jnp.uint32))
        assert float(fp.mean()) < 0.02

    def test_pack_roundtrip(self):
        f = bf.create(1 << 12, k=3)
        f = bf.insert(f, jnp.arange(1, 301, dtype=jnp.uint32))
        w = bf.pack_words(f)
        f2 = bf.unpack_words(w, f.block_bits, f.k, f.seed)
        assert (f2.bits == f.bits).all()
