"""Streaming ingestion engine (``repro.data.stream``) contract tests.

Three pillars of the single-compilation claim:

1. **one compilation** — the scan and step entry points trace exactly
   once per (config, shapes), asserted via the jit cache size across a
   multi-chunk run;
2. **buffer donation** — the compiled HLO carries input/output aliasing
   on every leaf of the donated table carry
   (``launch.hlo_census.input_output_aliases``), i.e. steady-state
   ingestion never copies the table arena;
3. **bit-exactness** — streaming output (keep masks, hit counts) and the
   final carry (table store included) match the per-batch eager
   reference leaf-for-leaf, INCLUDING across an in-graph compaction
   boundary (the ``lax.cond`` sweep fires mid-stream in these configs).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import single_value as sv
from repro.data import pipeline, stream
from repro.launch import hlo_census

_I = jnp.int32


def _workload(cfg, n_chunks, vocab, seed=0):
    rng = np.random.default_rng(seed)
    chunks = rng.integers(0, vocab, (n_chunks, cfg.chunk_batch,
                                     cfg.seq_len)).astype(np.int32)
    watch = pipeline.build_watchlist(
        rng.choice(vocab, size=max(vocab // 4, 2),
                   replace=False).astype(np.uint32))
    return jnp.asarray(chunks), watch


def _churn_cfg(**kw):
    """Small vocab + short ring + tight density: dedup churn tombstones
    the table fast enough that the in-graph compaction fires."""
    base = dict(seq_len=12, chunk_batch=8, dedup_capacity=512,
                forget_after=2, compact_every=3,
                max_tombstone_density=0.01)
    base.update(kw)
    return stream.StreamConfig(**base)


def test_scan_single_compilation_and_reuse():
    cfg = _churn_cfg()
    chunks, watch = _workload(cfg, 6, vocab=32)
    before = stream.stream_scan._cache_size()
    fin, _ = stream.stream_scan(stream.create_state(cfg), watch, chunks)
    after_first = stream.stream_scan._cache_size()
    assert after_first == before + 1, "scan did not compile exactly once"
    # fresh state, same shapes: the cached executable is reused verbatim
    fin2, _ = stream.stream_scan(stream.create_state(cfg), watch, chunks)
    assert stream.stream_scan._cache_size() == after_first, \
        "scan retraced on a same-shape call"
    assert int(fin2.counters.chunks) == 6


def test_step_single_compilation_across_chunks():
    cfg = _churn_cfg(seq_len=8)
    chunks, watch = _workload(cfg, 8, vocab=32, seed=1)
    state = stream.create_state(cfg)
    before = stream.stream_step._cache_size()
    state, _ = stream.stream_step(state, watch, chunks[0])
    assert stream.stream_step._cache_size() == before + 1
    for c in chunks[1:]:
        state, _ = stream.stream_step(state, watch, c)
    assert stream.stream_step._cache_size() == before + 1, \
        "per-chunk step retraced mid-stream"
    assert int(state.counters.chunks) == 8


def test_table_carry_is_donated():
    cfg = _churn_cfg()
    chunks, watch = _workload(cfg, 4, vocab=32, seed=2)
    state = stream.create_state(cfg)
    hlo = stream.compiled_stream_hlo(state, watch, chunks)
    aliases = hlo_census.input_output_aliases(hlo)
    assert aliases, "no input/output aliasing: donation was dropped"
    n_state = len(jax.tree_util.tree_leaves(state))
    donated = hlo_census.donated_param_numbers(hlo)
    # every leaf of the state carry (params 0..n-1 in flattening order,
    # table store included) must alias an output buffer
    assert donated == set(range(n_state)), (donated, n_state)
    kinds = {a["kind"] for a in aliases}
    assert kinds <= {"may-alias", "must-alias"}


def test_alias_parser_on_minimal_donated_fn():
    f = jax.jit(lambda a, b: (a + 1, b), donate_argnums=(0,))
    x = jnp.zeros((8,), _I)
    hlo = f.lower(x, x).compile().as_text()
    aliases = hlo_census.input_output_aliases(hlo)
    assert hlo_census.donated_param_numbers(hlo) == {0}
    assert all(a["param_index"] == () for a in aliases)


def test_stream_bit_exact_vs_eager_across_compaction():
    cfg = _churn_cfg()
    chunks, watch = _workload(cfg, 12, vocab=24, seed=3)

    fin, (keep, hits) = stream.stream_scan(
        stream.create_state(cfg), watch, chunks)
    ref_fin, rkeep, rhits = stream.reference_run(
        stream.create_state(cfg), watch, np.asarray(chunks))

    # the interesting case actually happened: ring expiry erased keys and
    # the lax.cond compaction fired mid-stream
    assert int(fin.counters.erased) > 0
    assert int(fin.counters.compactions) >= 1, \
        "compaction predicate never fired — config does not cover the branch"

    np.testing.assert_array_equal(np.asarray(keep), np.asarray(rkeep))
    np.testing.assert_array_equal(np.asarray(hits), np.asarray(rhits))
    for a, b in zip(jax.tree_util.tree_leaves(fin),
                    jax.tree_util.tree_leaves(ref_fin)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compaction_drops_tombstones_and_preserves_live_set():
    cfg = _churn_cfg()
    chunks, watch = _workload(cfg, 3 * cfg.compact_every, vocab=24, seed=4)
    state = stream.create_state(cfg)
    tombs, fired = [], []
    for i, c in enumerate(np.asarray(chunks)):
        prev = int(state.counters.compactions)
        state, _ = stream.stream_step(state, watch, jnp.asarray(c))
        tombs.append(int(state.counters.tombstone_slots))
        fired.append(int(state.counters.compactions) > prev)
    assert any(fired), "no in-graph compaction in the churn window"
    # density drops to zero across every firing chunk (the predicate saw
    # > limit pre-sweep; post-sweep the store is tombstone-free), while
    # non-firing chunks let churn tombstones accumulate
    assert all(t == 0 for t, f in zip(tombs, fired) if f), tombs
    assert any(t > 0 for t, f in zip(tombs, fired) if not f), tombs

    # live set preserved, erased keys absent: replay the semantics on the
    # final table — every fingerprint of the last `forget_after` chunks
    # that the dedup kept must still be present; expired rows of earlier
    # chunks must be gone
    last = np.asarray(chunks)[-1]
    fps = pipeline.sequence_fingerprints(jnp.asarray(last))
    _, found = sv.retrieve(state.table, fps)
    assert bool(jnp.all(found)), "live fingerprints lost by compaction"
    expired = np.asarray(chunks)[len(chunks) - cfg.forget_after - 1]
    efps = pipeline.sequence_fingerprints(jnp.asarray(expired))
    # fps of the expired chunk may collide with still-live window fps on
    # a tiny vocab; assert absence only for fps not re-ingested since
    window = {int(x) for c in np.asarray(chunks)[-cfg.forget_after:]
              for x in np.asarray(
                  pipeline.sequence_fingerprints(jnp.asarray(c)))}
    stale = jnp.asarray(
        [int(f) not in window for f in np.asarray(efps)])
    _, efound = sv.retrieve(state.table, efps)
    assert not bool(jnp.any(efound & stale)), \
        "expired fingerprints survived forget+compaction"


def test_stream_driver_matches_scan():
    cfg = _churn_cfg(forget_after=0, compact_every=0)
    chunks, watch = _workload(cfg, 5, vocab=64, seed=5)
    fin, (keep, hits) = stream.stream_scan(
        stream.create_state(cfg), watch, chunks)
    fin2, keep2, hits2 = stream.stream(
        stream.create_state(cfg), watch, list(np.asarray(chunks)))
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(keep2))
    np.testing.assert_array_equal(np.asarray(hits), np.asarray(hits2))
    for a, b in zip(jax.tree_util.tree_leaves(fin),
                    jax.tree_util.tree_leaves(fin2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stream_rejects_ragged_chunks():
    cfg = _churn_cfg()
    _, watch = _workload(cfg, 1, vocab=16, seed=6)
    bad = [np.zeros((cfg.chunk_batch, cfg.seq_len + 1), np.int32)]
    with pytest.raises(ValueError, match="fixed-shape"):
        stream.stream(stream.create_state(cfg), watch, bad)


def test_donated_table_entry_points():
    t = sv.create(1024)
    keys = jnp.arange(1, 129, dtype=jnp.uint32)
    vals = jnp.arange(128, dtype=jnp.uint32)
    t, st = sv.insert_donated(t, keys, vals)
    assert int(t.count) == 128
    hlo = sv.insert_donated.lower(t, keys, vals).compile().as_text()
    assert 0 in hlo_census.donated_param_numbers(hlo)
    t, erased = sv.erase_donated(t, keys[:64])
    assert int(jnp.sum(erased)) == 64 and int(t.count) == 64


def test_serve_table_traffic_latency_and_no_retrace():
    from repro.obs.registry import Registry
    from repro.obs.trace import Tracer
    from repro.serving import serve_loop

    rng = np.random.default_rng(9)

    def traffic(n):
        for _ in range(n):
            yield (jnp.asarray(rng.integers(1, 4000, 64), jnp.uint32),
                   jnp.asarray(rng.integers(0, 2**31, 64), jnp.uint32),
                   jnp.asarray(rng.integers(1, 4000, 64), jnp.uint32),
                   jnp.asarray(rng.integers(1, 4000, 32), jnp.uint32))

    tracer = Tracer(registry=Registry())
    t = sv.create(8192)
    t, tracer, steps = serve_loop.serve_table_traffic(
        t, traffic(6), tracer=tracer)
    assert steps == 6
    p = tracer.percentiles("serve.table_step")
    assert p["count"] == 6 and p["p99_s"] > 0
