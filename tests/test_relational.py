"""Relational operator tests: every join flavor and aggregate checked
against numpy/dict reference implementations, plus tombstoned build keys,
empty inputs, masks, and jax-vs-pallas backend agreement."""

import os
import subprocess
import sys
import textwrap
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multi_value as mv
from repro.relational import distinct as rdistinct
from repro.relational import groupby as rgroupby
from repro.relational import join as rjoin


# ---------------------------------------------------------------------------
# references
# ---------------------------------------------------------------------------

def ref_join(build_keys, probe_keys, how, build_live=None, probe_live=None):
    """Dict-based reference: returns (sorted pair list, matched mask)."""
    build_live = np.ones(len(build_keys), bool) if build_live is None else build_live
    probe_live = np.ones(len(probe_keys), bool) if probe_live is None else probe_live
    d = defaultdict(list)
    for i, k in enumerate(build_keys):
        if build_live[i]:
            d[int(k)].append(i)
    pairs, matched = [], []
    for j, k in enumerate(probe_keys):
        hits = d.get(int(k), []) if probe_live[j] else []
        matched.append(bool(hits) and bool(probe_live[j]))
        if not probe_live[j]:
            continue
        if how == "inner":
            pairs += [(i, j) for i in hits]
        elif how == "left":
            pairs += [(i, j) for i in hits] if hits else [(-1, j)]
        elif how == "semi" and hits:
            pairs.append((-1, j))
        elif how == "anti" and not hits:
            pairs.append((-1, j))
    return sorted(pairs), np.array(matched, bool)


def result_pairs(res):
    return sorted((int(b), int(p)) for b, p, v in
                  zip(res.build_idx, res.probe_idx, res.valid) if v)


def ref_groupby(keys, values, agg):
    groups = defaultdict(list)
    for k, v in zip(keys, values):
        groups[int(k)].append(int(v))
    out = {}
    for k, vs in groups.items():
        if agg == "sum":
            out[k] = int(np.sum(np.asarray(vs, np.uint32), dtype=np.uint32))
        elif agg == "min":
            out[k] = min(vs)
        elif agg == "max":
            out[k] = max(vs)
        elif agg == "count":
            out[k] = len(vs)
        elif agg == "mean":
            out[k] = float(np.float32(np.sum(np.asarray(vs, np.uint32),
                                             dtype=np.uint32))
                           / np.float32(len(vs)))
    return out


# ---------------------------------------------------------------------------
# joins
# ---------------------------------------------------------------------------

class TestJoin:
    @pytest.mark.parametrize("how", rjoin.HOW)
    def test_matches_reference_with_duplicates(self, how, rng):
        # duplicate keys on BOTH sides -> N:M fan-out
        bk = rng.integers(1, 40, 120).astype(np.uint32)
        pk = rng.integers(1, 60, 200).astype(np.uint32)
        cap = 4096
        res = jax.jit(lambda b, p: rjoin.hash_join(b, p, cap, how))(
            jnp.asarray(bk), jnp.asarray(pk))
        pairs, matched = ref_join(bk, pk, how)
        assert result_pairs(res) == pairs
        assert int(res.total) == len(pairs)
        np.testing.assert_array_equal(np.asarray(res.matched), matched)

    @pytest.mark.parametrize("how", rjoin.HOW)
    def test_tombstoned_build_keys(self, how, rng):
        # erased build keys must act as absent in every flavor
        bk = rng.choice(np.arange(1, 600, dtype=np.uint32), 150, replace=False)
        pk = rng.choice(bk, 80, replace=False)
        erased = bk[:50]
        table, _ = rjoin.build(jnp.asarray(bk))
        table, _ = mv.erase(table, jnp.asarray(erased))
        res = rjoin.probe(table, jnp.asarray(pk), 512, how)
        live = ~np.isin(bk, erased)
        pairs, matched = ref_join(bk, pk, how, build_live=live)
        assert result_pairs(res) == pairs
        np.testing.assert_array_equal(np.asarray(res.matched), matched)

    @pytest.mark.parametrize("how", rjoin.HOW)
    def test_probe_mask(self, how, rng):
        bk = rng.integers(1, 30, 60).astype(np.uint32)
        pk = rng.integers(1, 50, 90).astype(np.uint32)
        mask = rng.random(90) < 0.6
        res = rjoin.hash_join(jnp.asarray(bk), jnp.asarray(pk), 2048, how,
                              probe_mask=jnp.asarray(mask))
        pairs, matched = ref_join(bk, pk, how, probe_live=mask)
        assert result_pairs(res) == pairs
        np.testing.assert_array_equal(np.asarray(res.matched), matched)

    @pytest.mark.parametrize("how", rjoin.HOW)
    def test_empty_inputs(self, how):
        e = jnp.zeros((0,), jnp.uint32)
        ks = jnp.asarray([1, 2, 3], jnp.uint32)
        # empty build: inner/semi emit nothing, left/anti emit probe rows
        res = rjoin.hash_join(e, ks, 16, how)
        pairs, _ = ref_join(np.zeros(0, np.uint32), np.asarray(ks), how)
        assert result_pairs(res) == pairs
        # empty probe: nothing out
        res = rjoin.hash_join(ks, e, 16, how)
        assert int(res.total) == 0 and not bool(res.valid.any())
        # both empty
        res = rjoin.hash_join(e, e, 4, how)
        assert int(res.total) == 0

    def test_out_capacity_overflow_reports_total(self, rng):
        bk = np.repeat(np.arange(1, 11, dtype=np.uint32), 8)   # 10 keys x8
        pk = np.arange(1, 11, dtype=np.uint32)
        res = rjoin.hash_join(jnp.asarray(bk), jnp.asarray(pk), 24, "inner")
        assert int(res.total) == 80                  # true size via counting pass
        assert int(res.valid.sum()) == 24            # capacity-bounded output

    def test_count_matches_sizes_output(self, rng):
        bk = rng.integers(1, 20, 64).astype(np.uint32)
        pk = rng.integers(1, 30, 48).astype(np.uint32)
        table, _ = rjoin.build(jnp.asarray(bk))
        for how in rjoin.HOW:
            want = int(rjoin.count_matches(table, jnp.asarray(pk), how).sum())
            res = rjoin.probe(table, jnp.asarray(pk), max(want, 1), how)
            assert int(res.total) == want

    def test_gather_payload(self, rng):
        bk = np.asarray([1, 2, 3], np.uint32)
        bv = np.asarray([10, 20, 30], np.uint32)
        pk = np.asarray([2, 9, 1], np.uint32)
        pv = np.asarray([5, 6, 7], np.uint32)
        res = rjoin.hash_join(jnp.asarray(bk), jnp.asarray(pk), 8, "inner")
        bcols, pcols = rjoin.gather_payload(res, jnp.asarray(bv),
                                            jnp.asarray(pv))
        got = sorted((int(a), int(b)) for a, b, v in
                     zip(bcols, pcols, res.valid) if v)
        assert got == [(10, 7), (20, 5)]

    def test_backend_agreement_jax_vs_pallas(self, rng):
        bk = rng.integers(1, 50, 100).astype(np.uint32)
        pk = rng.integers(1, 80, 100).astype(np.uint32)
        for how in rjoin.HOW:
            a = rjoin.hash_join(jnp.asarray(bk), jnp.asarray(pk), 512, how,
                                backend="jax")
            b = rjoin.hash_join(jnp.asarray(bk), jnp.asarray(pk), 512, how,
                                backend="pallas")
            assert result_pairs(a) == result_pairs(b)
            assert int(a.total) == int(b.total)
            np.testing.assert_array_equal(np.asarray(a.matched),
                                          np.asarray(b.matched))


# ---------------------------------------------------------------------------
# group-by
# ---------------------------------------------------------------------------

class TestGroupBy:
    @pytest.mark.parametrize("agg", rgroupby.AGGS)
    def test_matches_reference(self, agg, rng):
        keys = rng.integers(1, 25, 300).astype(np.uint32)
        vals = rng.integers(0, 1 << 20, 300).astype(np.uint32)
        gk, out, live, table = jax.jit(
            lambda k, v, agg=agg: rgroupby.aggregate(k, v, 128, agg))(
                jnp.asarray(keys), jnp.asarray(vals))
        got = {int(k): (float(v) if agg == "mean" else int(v))
               for k, v, l in zip(gk, out, live) if l}
        ref = ref_groupby(keys, vals, agg)
        if agg == "mean":
            assert got.keys() == ref.keys()
            for k in ref:
                assert got[k] == pytest.approx(ref[k], rel=1e-5)
        else:
            assert got == ref
        assert int(table.count) == len(ref)

    def test_sum_wraps_u32(self):
        keys = jnp.asarray([5, 5], jnp.uint32)
        vals = jnp.asarray([0xFFFFFFFF, 2], jnp.uint32)
        _, out, live, _ = rgroupby.aggregate(keys, vals, 64, "sum")
        assert int(out[np.asarray(live)][0]) == 1   # mod 2^32

    def test_streaming_updates_and_lookup(self, rng):
        keys = rng.integers(1, 10, 200).astype(np.uint32)
        vals = rng.integers(0, 1000, 200).astype(np.uint32)
        table = rgroupby.create(64)
        for lo in range(0, 200, 50):                # 4 batches, same table
            table, _ = rgroupby.update(table, "sum",
                                       jnp.asarray(keys[lo:lo + 50]),
                                       jnp.asarray(vals[lo:lo + 50]))
        ref = ref_groupby(keys, vals, "sum")
        q = np.asarray(sorted(ref), np.uint32)
        got, found = rgroupby.lookup(table, "sum", jnp.asarray(q))
        assert found.all()
        assert [int(v) for v in got] == [ref[int(k)] for k in q]

    def test_mask_and_empty(self, rng):
        keys = rng.integers(1, 8, 60).astype(np.uint32)
        vals = rng.integers(0, 100, 60).astype(np.uint32)
        mask = rng.random(60) < 0.5
        gk, out, live, _ = rgroupby.aggregate(
            jnp.asarray(keys), jnp.asarray(vals), 64, "sum",
            mask=jnp.asarray(mask))
        ref = ref_groupby(keys[mask], vals[mask], "sum")
        got = {int(k): int(v) for k, v, l in zip(gk, out, live) if l}
        assert got == ref
        e = jnp.zeros((0,), jnp.uint32)
        _, _, live, table = rgroupby.aggregate(e, e, 32, "count")
        assert int(live.sum()) == 0 and int(table.count) == 0

    def test_backend_agreement_jax_vs_pallas(self, rng):
        keys = rng.integers(1, 30, 150).astype(np.uint32)
        vals = rng.integers(0, 1 << 16, 150).astype(np.uint32)
        for agg in rgroupby.AGGS:
            ga = rgroupby.aggregate(jnp.asarray(keys), jnp.asarray(vals),
                                    128, agg, backend="jax")[:3]
            gb = rgroupby.aggregate(jnp.asarray(keys), jnp.asarray(vals),
                                    128, agg, backend="pallas")[:3]
            for a, b in zip(ga, gb):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# distinct
# ---------------------------------------------------------------------------

class TestDistinct:
    def test_matches_reference(self, rng):
        keys = rng.integers(1, 50, 400).astype(np.uint32)
        uniq, n_uniq, first = jax.jit(
            lambda k: rdistinct.distinct(k, 128))(jnp.asarray(keys))
        _, ref_first = np.unique(keys, return_index=True)
        ref_mask = np.zeros(len(keys), bool)
        ref_mask[ref_first] = True
        np.testing.assert_array_equal(np.asarray(first), ref_mask)
        assert int(n_uniq) == len(ref_first)
        # first-occurrence order
        assert [int(u) for u in np.asarray(uniq)[:int(n_uniq)]] == \
            [int(k) for k in keys[ref_mask]]

    def test_streaming_across_batches(self, rng):
        dset = rdistinct.create(256)
        a = np.asarray([1, 2, 3, 2], np.uint32)
        b = np.asarray([3, 4, 1, 5], np.uint32)
        dset, fa = rdistinct.first_occurrence(dset, jnp.asarray(a))
        dset, fb = rdistinct.first_occurrence(dset, jnp.asarray(b))
        assert np.asarray(fa).tolist() == [True, True, True, False]
        assert np.asarray(fb).tolist() == [False, True, False, True]
        assert int(dset.count) == 5

    def test_empty_and_backend(self):
        e = jnp.zeros((0,), jnp.uint32)
        _, n, _ = rdistinct.distinct(e, 4)
        assert int(n) == 0
        k = jnp.asarray([7, 7, 8], jnp.uint32)
        for backend in ("jax", "pallas"):
            u, n, f = rdistinct.distinct(k, 4, backend=backend)
            assert int(n) == 2 and np.asarray(f).tolist() == [True, False,
                                                              True]


# ---------------------------------------------------------------------------
# pipeline stage + sharded join (subprocess: needs 8 host devices)
# ---------------------------------------------------------------------------

class TestPipelineStage:
    def test_dedup_join_aggregate(self):
        from repro.core import counting
        from repro.data import pipeline as dp
        cfg = dp.DataConfig(vocab_size=64, seq_len=16, global_batch=8, seed=1)
        toks = dp.synthetic_batch(cfg, 0)["tokens"]
        toks = jnp.concatenate([toks, toks[:2]])        # 2 duplicate seqs
        table = counting.create(1024)
        tracked = jnp.asarray([3, 7, 11], jnp.uint32)
        table, keep, hits = jax.jit(
            lambda t, x: dp.relational_stage(t, x, tracked))(table, toks)
        kn = np.asarray(keep)
        assert kn[:8].all() and not kn[8:].any()
        tn = np.asarray(toks)
        ref = np.array([int(np.isin(tn[i], [3, 7, 11]).sum())
                        for i in range(tn.shape[0])])
        np.testing.assert_array_equal(np.asarray(hits),
                                      np.where(kn, ref, 0))
        # prebuilt watchlist (hot path) + duplicate watchlist entries
        wl = dp.build_watchlist(jnp.asarray([3, 3, 7, 11, 7], jnp.uint32))
        table2 = counting.create(1024)
        table2, keep2, hits2 = jax.jit(
            lambda t, x, w: dp.relational_stage(t, x, w))(table2, toks, wl)
        np.testing.assert_array_equal(np.asarray(keep2), kn)
        np.testing.assert_array_equal(np.asarray(hits2),
                                      np.where(kn, ref, 0))


class TestLazyImportInsideJit:
    def test_first_import_inside_trace_no_tracer_leak(self):
        # repro.relational is imported lazily inside jitted pipeline code;
        # a module-level jnp constant would be created as a tracer on the
        # first trace and leak into the second jit call (fresh process so
        # the module is really first-imported inside the trace).
        env = {**os.environ, "PYTHONPATH": "src"}
        code = textwrap.dedent("""
            import jax, jax.numpy as jnp
            from repro.core import counting
            from repro.data import pipeline as dp
            toks = jnp.ones((2, 8), jnp.int32)
            tracked = jnp.asarray([1, 2], jnp.uint32)
            t1 = counting.create(64)
            t1, _, _ = jax.jit(
                lambda t, x: dp.relational_stage(t, x, tracked))(t1, toks)
            t2 = counting.create(64)
            t2, _, _ = jax.jit(
                lambda t, x: dp.relational_stage(t, x, tracked))(t2, toks)
            print('OK')
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=300, env=env,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))))
        assert r.returncode == 0, f"STDERR:\n{r.stderr[-3000:]}"
        assert "OK" in r.stdout


class TestShardedJoin:
    def test_partitioned_matches_reference(self):
        env = {**os.environ,
               "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
               "PYTHONPATH": "src"}
        code = textwrap.dedent("""
            import jax, jax.numpy as jnp, numpy as np
            from collections import defaultdict
            from repro.relational import join
            mesh = jax.make_mesh((8,), ('x',))
            rng = np.random.default_rng(0)
            bk = rng.integers(1, 300, 8 * 64).astype(np.uint32)
            pk = rng.integers(1, 400, 8 * 128).astype(np.uint32)
            d = defaultdict(list)
            for i, k in enumerate(bk):
                d[int(k)].append(i)
            refm = np.array([int(k) in d for k in pk])
            ref = sorted((i, j) for j, k in enumerate(pk)
                         for i in d.get(int(k), []))
            out = join.shard_join(mesh, 'x', jnp.asarray(bk),
                                  jnp.asarray(pk), 2048, 'inner', slack=4.0)
            assert int(np.asarray(out['overflow']).sum()) == 0
            got = sorted((int(b), int(p)) for b, p, v in
                         zip(out['build_idx'], out['probe_idx'],
                             out['valid']) if v)
            assert got == ref, 'pair mismatch'
            assert (np.asarray(out['matched']) == refm).all()
            for how, expect in (('semi', int(refm.sum())),
                                ('anti', int((~refm).sum()))):
                o = join.shard_join(mesh, 'x', jnp.asarray(bk),
                                    jnp.asarray(pk), 2048, how, slack=4.0)
                assert int(np.asarray(o['total']).sum()) == expect, how
            # composite two-column keys ride the same ownership exchange:
            # (hi, lo) tuples vs the equivalent u32-packed single words.
            # Output order is per-owner-shard and the two representations
            # hash to different owners, so compare the order-independent
            # contract: the global (build, probe) pair set, the
            # input-aligned matched mask, and the total
            bh, bl = bk >> 4, (bk & 15) | 1
            ph, plo = pk >> 4, (pk & 15) | 1
            oc = join.shard_join(mesh, 'x',
                                 (jnp.asarray(bh), jnp.asarray(bl)),
                                 (jnp.asarray(ph), jnp.asarray(plo)),
                                 2048, 'inner', slack=4.0)
            op = join.shard_join(mesh, 'x',
                                 jnp.asarray((bh << 4) | bl),
                                 jnp.asarray((ph << 4) | plo),
                                 2048, 'inner', slack=4.0)
            def pairs(o):
                return sorted((int(b), int(p)) for b, p, v in
                              zip(o['build_idx'], o['probe_idx'],
                                  o['valid']) if v)
            assert pairs(oc) == pairs(op), 'composite pair set mismatch'
            assert (np.asarray(oc['matched'])
                    == np.asarray(op['matched'])).all()
            assert (int(np.asarray(oc['total']).sum())
                    == int(np.asarray(op['total']).sum()))
            assert int(np.asarray(oc['overflow']).sum()) == 0
            print('OK')
        """)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=540, env=env,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))))
        assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
        assert "OK" in r.stdout
