"""Per-arch smoke tests: reduced same-family config, one forward/train step
on CPU, asserting output shapes + finiteness (assignment requirement f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model_zoo as zoo


def _batch_for(cfg, key, b=2, s=16):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((b, cfg.frontend_len, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((b, cfg.frontend_len, cfg.d_model),
                                     jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
class TestArchSmoke:
    def test_forward_loss_finite(self, arch):
        cfg = configs.get_smoke_config(arch)
        model = zoo.build(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        loss, metrics = jax.jit(model.loss)(params, _batch_for(cfg, key))
        assert jnp.isfinite(loss), f"{arch} loss not finite"
        assert 1.0 < float(loss) < 20.0

    def test_train_step_reduces_loss(self, arch):
        from repro.training import optimizer as opt_mod
        from repro.training import train_loop as tl
        cfg = configs.get_smoke_config(arch)
        model = zoo.build(cfg)
        ocfg = opt_mod.OptConfig(lr=3e-3, warmup_steps=1, total_steps=30)
        state = tl.init_state(model, ocfg, jax.random.PRNGKey(0))
        step = jax.jit(tl.make_train_step(model, ocfg))
        batch = _batch_for(cfg, jax.random.PRNGKey(1), b=4, s=16)
        losses = []
        for _ in range(8):
            state, m = step(state, batch)   # overfit one batch
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], f"{arch}: {losses}"

    def test_decode_step_shapes(self, arch):
        cfg = configs.get_smoke_config(arch)
        model = zoo.build(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        b, max_seq = 2, 24
        cache = model.init_cache(b, max_seq)
        tok = jnp.zeros((b, 1), jnp.int32)
        logits, cache2 = jax.jit(model.decode_step)(params, cache, tok,
                                                    jnp.int32(0))
        assert logits.shape == (b, 1, cfg.vocab_size)
        assert jnp.isfinite(logits).all()
        # cache structure preserved
        assert (jax.tree_util.tree_structure(cache)
                == jax.tree_util.tree_structure(cache2))

    def test_decode_matches_forward(self, arch):
        """Teacher-forced decode must reproduce the parallel forward.

        MoE configs use a no-drop capacity factor here: capacity-based
        dropping legitimately differs between a (B*S)-token forward and a
        B-token decode step — equality only holds when nothing drops.
        """
        import dataclasses as dc
        cfg = configs.get_smoke_config(arch)
        if cfg.family == "audio":
            pytest.skip("enc-dec positions verified in test_encdec_consistency")
        if cfg.moe is not None:
            cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0))
        model = zoo.build(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        b, s = 2, 8
        batch = _batch_for(cfg, key, b=b, s=s)
        from repro.models import transformer as tf
        logits_fwd, _ = tf.lm_logits(cfg, params, batch["tokens"],
                                     batch.get("patches"))
        if cfg.family == "vlm":
            logits_fwd = logits_fwd[:, cfg.frontend_len:]
            pytest.skip("vlm decode starts mid-sequence; covered by shapes test")
        cache = model.init_cache(b, s)
        outs = []
        for i in range(s):
            lg, cache = model.decode_step(params, cache,
                                          batch["tokens"][:, i:i + 1],
                                          jnp.int32(i))
            outs.append(lg[:, 0])
        logits_dec = jnp.stack(outs, axis=1)
        # MoE: discrete top-k routing amplifies bf16 noise (a near-tie in
        # router logits flips an expert choice) — wider tolerance
        tol = 0.5 if cfg.moe is not None else 0.15
        np.testing.assert_allclose(np.asarray(logits_dec),
                                   np.asarray(logits_fwd),
                                   rtol=tol, atol=tol)

    def test_full_config_exact_spec(self, arch):
        """The FULL config matches the assignment table exactly."""
        spec = {
            "smollm-360m": (32, 960, 15, 5, 2560, 49152),
            "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
            "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
            "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
            "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
            "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
            "whisper-small": (12, 768, 12, 12, 3072, 51865),
            "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
            "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
            "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        }[arch]
        cfg = configs.get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == spec


def test_encdec_consistency():
    """Whisper decode with cross-KV cache matches teacher-forced decode."""
    from repro.models import encdec as ed
    cfg = configs.get_smoke_config("whisper-small")
    key = jax.random.PRNGKey(0)
    params = ed.init_encdec(cfg, key)
    b, s = 2, 6
    frames = jax.random.normal(key, (b, cfg.frontend_len, cfg.d_model),
                               jnp.float32).astype(jnp.bfloat16) * 0.1
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    enc = ed.encode(cfg, params, frames)
    logits_fwd = ed.decode_train(cfg, params, enc, toks)
    cache = ed.init_encdec_cache(cfg, b, s, cfg.frontend_len)
    cache = ed.encdec_prefill(cfg, params, frames, cache)
    outs = []
    for i in range(s):
        lg, cache = ed.encdec_decode_step(cfg, params, cache, toks[:, i:i + 1],
                                          jnp.int32(i))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits_fwd), rtol=0.15, atol=0.15)


def test_moe_routes_to_multiple_experts():
    from repro.models import moe as moe_mod
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, 32, 64, num_experts=8, num_shared=1,
                         dtype=jnp.float32)
    x = jax.random.normal(key, (2, 16, 32))
    y, aux = moe_mod.moe_ffn(p, x, num_experts=8, top_k=2)
    assert y.shape == x.shape and jnp.isfinite(y).all()
    assert 0.5 < float(aux) < 8.1      # balanced-ish routing at init


def test_param_counts_match_published():
    published = {
        "mistral-large-123b": (123e9, 0.06),
        "deepseek-v2-236b": (236e9, 0.06),
        "dbrx-132b": (132e9, 0.06),
        "jamba-1.5-large-398b": (398e9, 0.06),
    }
    for arch, (n, tol) in published.items():
        got = configs.get_config(arch).param_count()
        assert abs(got - n) / n < tol, f"{arch}: {got / 1e9:.1f}B vs {n / 1e9}B"
    assert abs(configs.get_config("jamba-1.5-large-398b").active_param_count()
               - 94e9) / 94e9 < 0.1


def test_chunked_attention_matches_naive():
    from repro.models import attention as attn
    key = jax.random.PRNGKey(0)
    b, s, hkv, rep, hd = 2, 256, 2, 3, 16
    q = jax.random.normal(key, (b, s, hkv, rep, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, hd))
    out = attn.chunked_causal_attention(q, k, v, q_chunk=64, k_chunk=64)
    # naive reference
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bhrqk,bkhd->bqhrd", w, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


def test_chunked_attention_ragged_and_kv_valid():
    from repro.models import attention as attn
    key = jax.random.PRNGKey(0)
    b, sq, sk, hkv, hd = 1, 100, 150, 2, 8
    q = jax.random.normal(key, (b, sq, hkv, 1, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, hkv, hd))
    out = attn.chunked_causal_attention(q, k, v, causal=False, q_chunk=64,
                                        k_chunk=64, kv_valid=120)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", q, k[:, :120]) / np.sqrt(hd)
    w = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bhrqk,bkhd->bqhrd", w, v[:, :120])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)
