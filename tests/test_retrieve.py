"""Fused-retrieval parity suite: the single-walk bulk-retrieval engine
(repro.core.bulk_retrieve, backend="jax") must be *bit-exact* against the
two-walk count+gather reference (backend="scan") — identical values,
offsets, counts, found/erased masks, and post-erase store planes — across
duplicate probe keys, masks, tombstone-riddled tables, ``out_capacity``
overflow truncation, u64 (2-word) keys, empty batches and ``n=0`` /
``out_capacity=0`` edges.  The Pallas walk tile joins the same contract
where the kernel path applies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bulk_retrieve as br
from repro.core import counting as ct
from repro.core import hashset as hs
from repro.core import multi_value as mv
from repro.core import single_value as sv
from repro.relational import join


def _pair(create_fn, **kw):
    return create_fn(backend="jax", **kw), create_fn(backend="scan", **kw)


def assert_same(*pairs):
    for a, b in pairs:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def assert_stores_equal(ta, tb):
    for pa, pb in zip(jax.tree_util.tree_leaves(ta.store),
                      jax.tree_util.tree_leaves(tb.store)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert int(ta.count) == int(tb.count)


def _mv_pair(n_pairs=300, key_hi=25, capacity=1024, window=16, seed=0, **kw):
    """Identical multi-value tables on both backends (insert parity is
    covered by test_bulk; here it just provides the fixture)."""
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(1, key_hi, n_pairs, dtype=np.uint32))
    vals = jnp.arange(n_pairs, dtype=jnp.uint32)
    tj, ts = _pair(lambda **k: mv.create(capacity, window=window, **k, **kw))
    tj, _ = mv.insert(tj, keys, vals)
    ts, _ = mv.insert(ts, keys, vals)
    assert_stores_equal(tj, ts)
    return tj, ts, rng


def _assert_retrieval_parity(tj, ts, q, out_capacity, mask=None):
    cj = mv.count_values(tj, q, mask)
    cs = mv.count_values(ts, q, mask)
    assert_same((cj, cs))
    vj, oj, c2j = mv.retrieve_all(tj, q, out_capacity, mask)
    vs, os_, c2s = mv.retrieve_all(ts, q, out_capacity, mask)
    assert_same((vj, vs), (oj, os_), (c2j, c2s))
    return vj, oj, cj


class TestRetrieveAllParity:
    def test_duplicate_probe_keys(self):
        """Duplicates walk once in the engine yet must fan out full copies."""
        tj, ts, _ = _mv_pair()
        q = jnp.asarray([3, 3, 3, 7, 3, 9, 7, 7, 3], jnp.uint32)
        _assert_retrieval_parity(tj, ts, q, out_capacity=200)

    def test_masks_drop_queries_entirely(self):
        tj, ts, rng = _mv_pair(seed=1)
        q = jnp.asarray(rng.integers(1, 40, 80, dtype=np.uint32))
        mask = jnp.asarray(rng.random(80) < 0.5)
        _, _, counts = _assert_retrieval_parity(tj, ts, q, 400, mask)
        assert (np.asarray(counts)[~np.asarray(mask)] == 0).all()

    @pytest.mark.parametrize("out_capacity", [0, 1, 7, 64])
    def test_out_capacity_overflow_truncation(self, out_capacity):
        """Truncation must drop exactly the tail and keep offsets/counts
        describing the UNtruncated layout on both backends."""
        tj, ts, rng = _mv_pair(n_pairs=200, key_hi=10, seed=2)
        q = jnp.asarray(rng.integers(1, 12, 30, dtype=np.uint32))
        vj, oj, cj = _assert_retrieval_parity(tj, ts, q, out_capacity)
        total = int(np.asarray(oj)[-1])
        assert total > out_capacity          # the case actually overflows
        assert int(np.asarray(cj).sum()) == total

    def test_tombstone_riddled_table(self):
        """Erase most keys, then query a mix of live, erased and absent
        keys — tombstones must not stop the walk on either backend."""
        tj, ts, _ = _mv_pair(n_pairs=400, key_hi=20, capacity=1024, seed=3)
        dead = jnp.arange(1, 15, dtype=jnp.uint32)
        tj, ej = mv.erase(tj, dead)
        ts, es = mv.erase(ts, dead)
        assert_same((ej, es))
        assert_stores_equal(tj, ts)
        q = jnp.asarray([1, 5, 14, 15, 16, 19, 99, 5, 15], jnp.uint32)
        _assert_retrieval_parity(tj, ts, q, 300)

    def test_u64_two_word_keys_and_values(self):
        rng = np.random.default_rng(4)
        kk = rng.integers(0, 2 ** 32 - 2, (60, 2), dtype=np.uint32)
        kk = np.concatenate([kk, kk[:20]])
        vv = jnp.asarray(rng.integers(0, 2 ** 32 - 2, (80, 2),
                                      dtype=np.uint32))
        tj, ts = _pair(lambda **k: mv.create(512, key_words=2, value_words=2,
                                             window=8, **k))
        tj, _ = mv.insert(tj, jnp.asarray(kk), vv)
        ts, _ = mv.insert(ts, jnp.asarray(kk), vv)
        q = jnp.asarray(np.concatenate([kk[:30], kk[:10],
                                        rng.integers(0, 2 ** 32 - 2, (10, 2),
                                                     dtype=np.uint32)]))
        qm = jnp.asarray(rng.random(50) < 0.8)
        _assert_retrieval_parity(tj, ts, q, 120, qm)

    def test_empty_batch(self):
        tj, ts, _ = _mv_pair(seed=5)
        q = jnp.zeros((0,), jnp.uint32)
        for oc in (0, 8):
            vj, oj, cj = _assert_retrieval_parity(tj, ts, q, oc)
            assert oj.shape == (1,) and int(oj[0]) == 0
            assert cj.shape == (0,)

    def test_empty_table_shortcut(self):
        """count==0 short-cuts the walk; results must still match the
        reference, which walks."""
        tj, ts = _pair(lambda **k: mv.create(256, **k))
        q = jnp.asarray([1, 2, 3, 1], jnp.uint32)
        _assert_retrieval_parity(tj, ts, q, 16)

    @pytest.mark.parametrize("layout", ["soa", "aos"])
    def test_layouts(self, layout):
        tj, ts, rng = _mv_pair(seed=6, layout=layout)
        q = jnp.asarray(rng.integers(1, 40, 60, dtype=np.uint32))
        _assert_retrieval_parity(tj, ts, q, 300)

    def test_max_probes_exhaustion(self):
        """A tiny max_probes truncates the walk identically on both paths."""
        rng = np.random.default_rng(7)
        keys = jnp.asarray(rng.integers(1, 8, 120, dtype=np.uint32))
        tj, ts = _pair(lambda **k: mv.create(64, window=4, max_probes=3, **k))
        tj, _ = mv.insert(tj, keys, keys * 5)
        ts, _ = mv.insert(ts, keys, keys * 5)
        q = jnp.asarray(rng.integers(1, 10, 40, dtype=np.uint32))
        _assert_retrieval_parity(tj, ts, q, 200)


class TestSingleValueRetrieveParity:
    def test_duplicates_and_missing(self):
        rng = np.random.default_rng(8)
        keys = jnp.asarray(rng.permutation(
            np.arange(1, 120, dtype=np.uint32)))
        tj, ts = _pair(lambda **k: sv.create(512, **k))
        tj, _ = sv.insert(tj, keys, keys * 3)
        ts, _ = sv.insert(ts, keys, keys * 3)
        q = jnp.asarray(rng.integers(1, 200, 150, dtype=np.uint32))
        assert_same(*zip(sv.retrieve(tj, q), sv.retrieve(ts, q)))
        assert_same((sv.contains(tj, q), sv.contains(ts, q)))

    def test_u64_keys_wide_values(self):
        rng = np.random.default_rng(9)
        kk = jnp.asarray(rng.integers(0, 2 ** 32 - 2, (70, 2),
                                      dtype=np.uint32))
        vv = jnp.asarray(rng.integers(0, 2 ** 32 - 2, (70, 2),
                                      dtype=np.uint32))
        tj, ts = _pair(lambda **k: sv.create(256, key_words=2, value_words=2,
                                             window=8, **k))
        tj, _ = sv.insert(tj, kk, vv)
        ts, _ = sv.insert(ts, kk, vv)
        q = jnp.concatenate([kk[:40], kk[:10]])
        assert_same(*zip(sv.retrieve(tj, q), sv.retrieve(ts, q)))

    def test_empty_batch(self):
        tj, ts = _pair(lambda **k: sv.create(128, **k))
        q = jnp.zeros((0,), jnp.uint32)
        vj, fj = sv.retrieve(tj, q)
        vs, fs = sv.retrieve(ts, q)
        assert_same((vj, vs), (fj, fs))
        assert vj.shape == (0,) and fj.shape == (0,)

    def test_counting_counts(self):
        rng = np.random.default_rng(10)
        keys = jnp.asarray(rng.integers(1, 30, 200, dtype=np.uint32))
        tj, ts = _pair(lambda **k: ct.create(256, **k))
        tj, _ = ct.insert(tj, keys)
        ts, _ = ct.insert(ts, keys)
        q = jnp.asarray(rng.integers(1, 40, 60, dtype=np.uint32))
        assert_same((ct.counts(tj, q), ct.counts(ts, q)))


class TestEraseParity:
    def test_single_value_duplicates_and_masks(self):
        rng = np.random.default_rng(11)
        keys = jnp.arange(1, 80, dtype=jnp.uint32)
        tj, ts = _pair(lambda **k: sv.create(256, **k))
        tj, _ = sv.insert(tj, keys, keys)
        ts, _ = sv.insert(ts, keys, keys)
        q = jnp.asarray([1, 1, 2, 2, 2, 3, 99, 4, 1], jnp.uint32)
        m = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0, 1], bool)
        tj, ej = sv.erase(tj, q, m)
        ts, es = sv.erase(ts, q, m)
        assert_same((ej, es))
        assert_stores_equal(tj, ts)

    def test_single_value_all_masked_group(self):
        """A key appearing only with mask=False must not be erased and must
        not disturb the walk (its group has no representative)."""
        keys = jnp.arange(1, 30, dtype=jnp.uint32)
        tj, ts = _pair(lambda **k: sv.create(128, **k))
        tj, _ = sv.insert(tj, keys, keys)
        ts, _ = sv.insert(ts, keys, keys)
        q = jnp.asarray([5, 6, 6, 7], jnp.uint32)
        m = jnp.asarray([True, False, False, True])
        tj, ej = sv.erase(tj, q, m)
        ts, es = sv.erase(ts, q, m)
        assert_same((ej, es))
        assert_stores_equal(tj, ts)
        assert bool(sv.contains(tj, jnp.asarray([6], jnp.uint32))[0])

    def test_multi_value_batched_tombstones(self):
        tj, ts, rng = _mv_pair(n_pairs=250, key_hi=15, seed=12)
        q = jnp.asarray([1, 3, 3, 5, 99, 1], jnp.uint32)
        tj, cj = mv.erase(tj, q)
        ts, cs = mv.erase(ts, q)
        assert_same((cj, cs))
        assert_stores_equal(tj, ts)
        # erased keys retrieve empty afterwards, on both backends
        _assert_retrieval_parity(tj, ts, q, 100)
        assert int(mv.count_values(tj, jnp.asarray([1, 3, 5], jnp.uint32)).sum()) == 0

    def test_multi_value_empty_batch(self):
        tj, ts, _ = _mv_pair(seed=13)
        q = jnp.zeros((0,), jnp.uint32)
        tj, cj = mv.erase(tj, q)
        ts, cs = mv.erase(ts, q)
        assert_same((cj, cs))
        assert_stores_equal(tj, ts)

    def test_hashset_remove(self):
        keys = jnp.asarray([5, 9, 11, 13], jnp.uint32)
        sj, ss = _pair(lambda **k: hs.create(128, **k))
        sj, _ = hs.add(sj, keys)
        ss, _ = hs.add(ss, keys)
        q = jnp.asarray([9, 9, 13, 7], jnp.uint32)
        sj, rj = hs.remove(sj, q)
        ss, rs = hs.remove(ss, q)
        assert_same((rj, rs))
        assert_stores_equal(sj, ss)


class TestPallasParity:
    def test_count_and_retrieve_all(self):
        rng = np.random.default_rng(14)
        keys = jnp.asarray(rng.integers(1, 20, 200, dtype=np.uint32))
        vals = jnp.arange(200, dtype=jnp.uint32)
        tp = mv.create(512, window=8, backend="pallas")
        ts = mv.create(512, window=8, backend="scan")
        tp, _ = mv.insert(tp, keys, vals)
        ts, _ = mv.insert(ts, keys, vals)
        assert_stores_equal(tp, ts)
        q = jnp.asarray(rng.integers(1, 30, 60, dtype=np.uint32))
        qm = jnp.asarray(rng.random(60) < 0.7)
        assert_same((mv.count_values(tp, q, qm), mv.count_values(ts, q, qm)))
        for oc in (0, 11, 300):
            a = mv.retrieve_all(tp, q, oc, qm)
            b = mv.retrieve_all(ts, q, oc, qm)
            assert_same(*zip(a, b))

    def test_single_value_lookup_dispatch(self):
        keys = jnp.arange(1, 60, dtype=jnp.uint32)
        tp = sv.create(256, backend="pallas")
        ts = sv.create(256, backend="scan")
        tp, _ = sv.insert(tp, keys, keys ^ 21)
        ts, _ = sv.insert(ts, keys, keys ^ 21)
        q = jnp.asarray([1, 1, 7, 99, 58], jnp.uint32)
        assert_same(*zip(sv.retrieve(tp, q), sv.retrieve(ts, q)))


class TestArenaInvariant:
    def test_arena_ranks_are_contiguous_per_representative(self):
        """Every representative's arena entries must carry ranks 0..cnt-1
        exactly once — the collision-free-placement invariant the
        compaction gather relies on."""
        tj, _, rng = _mv_pair(n_pairs=300, key_hi=12, seed=15)
        q = jnp.asarray(rng.integers(1, 15, 40, dtype=np.uint32))
        keys_n = sv.normalize_words(q, 1, "keys")
        live = jnp.ones((40,), bool)
        is_rep, rep_of = br.group_queries(keys_n, live)
        words = sv.key_hash_word(keys_n)
        cnt, qa, ra = br.fused_walk(br._tstatic(tj), tj.store, keys_n, words,
                                    is_rep, collect=True, count=tj.count)
        cnt, qa, ra = map(np.asarray, (cnt, qa, ra))
        is_rep = np.asarray(is_rep)
        for r in np.nonzero(is_rep)[0]:
            ranks = sorted(ra[qa == r].tolist())
            assert ranks == list(range(cnt[r])), f"rep {r}: {ranks}"


@pytest.mark.parametrize("seed", range(6))
def test_randomized_adversarial_parity(seed):
    """Randomized end-to-end: build (dups+mask) -> erase -> fused vs
    reference count/retrieve/erase across schemes, windows, layouts,
    capacities and out_capacity truncation — bit-exact."""
    r = np.random.default_rng(seed)
    n = int(r.integers(20, 200))
    key_hi = int(r.integers(4, 60))
    keys = jnp.asarray(r.integers(1, key_hi, n, dtype=np.uint32))
    vals = jnp.asarray(r.integers(0, 2 ** 32 - 2, n, dtype=np.uint32))
    mask = jnp.asarray(r.random(n) < 0.7)
    window = int(r.choice([1, 4, 8, 32]))
    scheme = str(r.choice(["cops", "linear", "quadratic"]))
    layout = str(r.choice(["soa", "aos"]))
    cap = int(r.choice([64, 256]))
    mp = int(r.choice([8, 64]))
    mk = lambda **kw: mv.create(cap, window=window, scheme=scheme,
                                layout=layout, max_probes=mp, **kw)
    tj, ts = _pair(mk)
    tj, _ = mv.insert(tj, keys, vals, mask)
    ts, _ = mv.insert(ts, keys, vals, mask)
    nq = int(r.integers(1, 80))
    q = jnp.asarray(r.integers(1, key_hi + 10, nq, dtype=np.uint32))
    qm = jnp.asarray(r.random(nq) < 0.8)
    total = int(np.asarray(mv.count_values(ts, q, qm)).sum())
    for oc in {0, max(total // 3, 1), total, total + 8}:
        _assert_retrieval_parity(tj, ts, q, oc, qm)
    tj, ej = mv.erase(tj, q[:nq // 2])
    ts, es = mv.erase(ts, q[:nq // 2])
    assert_same((ej, es))
    assert_stores_equal(tj, ts)
    _assert_retrieval_parity(tj, ts, q, max(total, 1))


class TestJoinLeftOuterTruncation:
    def test_left_outer_clip_keeps_valid_total_consistent(self):
        """Regression: the left-outer gather clips inner positions to
        out_capacity-1; when out_capacity < total the truncated result
        must still report the full total, mark exactly the first
        out_capacity rows valid, and agree with the scan backend."""
        bkeys = jnp.asarray([1, 1, 1, 2, 2, 3], jnp.uint32)
        pkeys = jnp.asarray([1, 9, 2, 1, 8], jnp.uint32)
        table_j, _ = join.build(bkeys, backend="jax")
        table_s, _ = join.build(bkeys, backend="scan")
        full = join.probe(table_j, pkeys, 32, how="left")
        total = int(full.total)              # 3 + 1 + 2 + 3 + 1 = 10
        assert total == 10
        for oc in (1, 4, total - 1):
            rj = join.probe(table_j, pkeys, oc, how="left")
            rs = join.probe(table_s, pkeys, oc, how="left")
            assert_same((rj.build_idx, rs.build_idx),
                        (rj.probe_idx, rs.probe_idx),
                        (rj.valid, rs.valid), (rj.matched, rs.matched),
                        (rj.total, rs.total))
            assert int(rj.total) == total    # truncation is silent but honest
            assert int(np.asarray(rj.valid).sum()) == min(oc, total)
            # valid rows must be a prefix and match the untruncated head
            np.testing.assert_array_equal(
                np.asarray(rj.build_idx)[:oc][np.asarray(rj.valid)],
                np.asarray(full.build_idx)[:oc][np.asarray(rj.valid)])

    @pytest.mark.parametrize("how", join.HOW)
    def test_all_flavors_fused_vs_scan(self, how):
        rng = np.random.default_rng(16)
        bkeys = jnp.asarray(rng.integers(1, 15, 80, dtype=np.uint32))
        pkeys = jnp.asarray(rng.integers(1, 25, 50, dtype=np.uint32))
        pm = jnp.asarray(rng.random(50) < 0.8)
        tb_j, _ = join.build(bkeys, backend="jax")
        tb_s, _ = join.build(bkeys, backend="scan")
        cj = join.count_matches(tb_j, pkeys, how, mask=pm)
        cs = join.count_matches(tb_s, pkeys, how, mask=pm)
        assert_same((cj, cs))
        oc = int(np.asarray(cj).sum())
        for cap2 in (max(oc // 2, 1), oc + 4):
            rj = join.probe(tb_j, pkeys, cap2, how=how, mask=pm)
            rs = join.probe(tb_s, pkeys, cap2, how=how, mask=pm)
            assert_same((rj.build_idx, rs.build_idx),
                        (rj.probe_idx, rs.probe_idx),
                        (rj.valid, rs.valid), (rj.matched, rs.matched),
                        (rj.total, rs.total))
