"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real (single) device; multi-device tests spawn subprocesses."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def unique_keys(rng, n, lo=1, hi=0xFFFFFF00):
    """Distinct u32 keys avoiding the EMPTY/TOMBSTONE sentinels."""
    ks = rng.choice(np.arange(lo, lo + 4 * n, dtype=np.uint32), size=n,
                    replace=False)
    return ks
