"""End-to-end behaviour tests for the paper's system.

The paper's headline behaviours, validated at CPU scale:
  1. bulk build + query at high load factor (the Fig-5 scenario),
  2. multi-value robustness across key multiplicities (Fig-7),
  3. bucket-list storage density beating pure OA at high multiplicity,
  4. the metagenomics pipeline (Fig-8): minhash -> bucket-list -> classify,
  5. the end-to-end LM training driver (launch.train) and serving driver.
"""

import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucket_list as bl
from repro.core import multi_value as mv
from repro.core import single_value as sv


def test_bulk_build_query_at_097_density():
    """Paper §V-A: WarpCore stays functional at rho = 0.97 where competing
    schemes degrade/fail; scalar-LP baseline needs far longer probe chains."""
    t = sv.create(4096, window=32)
    n = int(t.capacity * 0.97)
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.choice(np.arange(1, 10 * n, dtype=np.uint32),
                                  size=n, replace=False))
    vals = keys ^ jnp.uint32(0x5A5A)
    t, st = sv.insert(t, keys, vals)
    assert (np.asarray(st) == 0).all()
    got, found = sv.retrieve(t, keys)
    assert found.all() and (got == vals).all()


def test_multi_value_flat_throughput_structure():
    """Fig 7 structure: total probe work per retrieved value stays bounded
    as multiplicity grows (COPS retrieves multiple values per window)."""
    for r in (1, 8, 32):
        t = mv.create(8192, window=32)
        n_keys = 2048 // r
        keys = jnp.asarray(np.repeat(np.arange(1, n_keys + 1,
                                               dtype=np.uint32), r))
        t, st = mv.insert(t, keys, jnp.arange(len(keys), dtype=jnp.uint32))
        assert (np.asarray(st) == 0).all()
        q = jnp.arange(1, n_keys + 1, dtype=jnp.uint32)
        cnt = np.asarray(mv.count_values(t, q))
        assert (cnt == r).all()


def test_bucket_list_denser_than_oa_at_high_multiplicity():
    """§IV-C: for r >> 1 the bucket list stores each key once, the OA table
    r times — bucket list wins on stored-pairs per allocated slot."""
    r, n_keys = 32, 64
    keys = jnp.asarray(np.repeat(np.arange(1, n_keys + 1, dtype=np.uint32), r))
    vals = jnp.arange(len(keys), dtype=jnp.uint32)

    oa = mv.create(4096, window=32)
    oa, _ = mv.insert(oa, keys, vals)
    oa_slots = oa.capacity * 2                       # key+value words
    oa_useful = int(oa.count)                        # pairs stored

    t = bl.create(128, pool_capacity=n_keys * r + 200, s0=r, growth=1.0)
    t, _ = bl.insert(t, keys, vals)
    bl_slots = t.key_store.capacity * 3 + t.pool_capacity
    bl_useful = int(sum(np.asarray(bl.count_values(
        t, jnp.arange(1, n_keys + 1, dtype=jnp.uint32)))))

    assert bl_useful == oa_useful == n_keys * r
    density_oa = oa_useful * 2 / oa_slots
    density_bl = (bl_useful + n_keys) / bl_slots
    assert density_bl > density_oa


def test_metagenomics_pipeline_classifies():
    """Mini Fig-8: build a reference DB from synthetic genomes, classify
    reads back to their source genome via minhash + bucket list."""
    from repro.kernels.minhash import ops as mh
    rng = np.random.default_rng(42)
    k, s = 16, 24
    genomes = [rng.integers(0, 4, 2000).astype(np.uint8) for _ in range(4)]

    table = bl.create(8192, pool_capacity=1 << 14, s0=2, growth=1.5)
    for gid, g in enumerate(genomes):
        sk = np.asarray(mh.sketch_reads(jnp.asarray(g[None]), k=k, s=256))
        hashes = sk[0][sk[0] != 0xFFFFFFFF]
        hashes = np.minimum(hashes, 0xFFFFFFFD)
        table, st = bl.insert(table, jnp.asarray(hashes),
                              jnp.full((len(hashes),), gid, jnp.uint32))
        assert (np.asarray(st) == 0).all()

    correct = 0
    n_reads = 12
    for _ in range(n_reads):
        gid = int(rng.integers(0, 4))
        start = int(rng.integers(0, 1500))
        read = genomes[gid][start:start + 400]
        sk = np.asarray(mh.sketch_reads(jnp.asarray(read[None]), k=k, s=s))
        q = sk[0][sk[0] != 0xFFFFFFFF]
        q = np.minimum(q, 0xFFFFFFFD)
        out, off, cnt = bl.retrieve_all(table, jnp.asarray(q),
                                        out_capacity=len(q) * 8)
        votes = np.bincount(np.asarray(out)[:int(off[-1])], minlength=4)
        if votes.argmax() == gid:
            correct += 1
    assert correct >= n_reads * 0.75, f"classified {correct}/{n_reads}"


def test_train_driver_end_to_end(tmp_path):
    """launch.train: real CLI run with checkpointing + resume."""
    env = {**os.environ, "PYTHONPATH": "src"}
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "smollm-360m", "--smoke", "--batch", "4", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--log-every", "5", "--lr", "3e-3"]
    r = subprocess.run(base + ["--steps", "10"], capture_output=True,
                       text=True, timeout=500, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "step 9" in r.stdout
    r2 = subprocess.run(base + ["--steps", "14", "--resume"],
                        capture_output=True, text=True, timeout=500, env=env)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 10" in r2.stdout


def test_serve_driver_end_to_end():
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-m", "repro.launch.serve",
                        "--arch", "smollm-360m", "--smoke", "--batch", "2",
                        "--prompt-len", "8", "--max-new", "8"],
                       capture_output=True, text=True, timeout=500, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout
